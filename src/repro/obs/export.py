"""Telemetry exporters: Chrome trace-event JSONL (Perfetto) + Prometheus.

Two serializations of one :class:`repro.obs.Registry`:

* :func:`write_chrome_trace` — the Trace Event format
  (https://ui.perfetto.dev loads it directly). The file is a valid JSON
  array written one event per line, so it doubles as JSONL: stripping
  the bracket lines and trailing commas leaves one ``json.loads``-able
  object per line (:func:`read_chrome_trace` does exactly that). The
  registry's final aggregate snapshot rides along as a single
  ``repro.registry_snapshot`` instant event, so one file carries both
  the timeline and the counters/gauges/histograms —
  ``python -m repro.launch.obs_report`` renders either view from it.

* :func:`prometheus_text` — the Prometheus text exposition format
  (counters / gauges verbatim; log-bucket histograms as classic
  cumulative ``_bucket{le=...}`` series with powers-of-2^(1/B) bounds),
  ready to serve from a ``/metrics`` endpoint or push to a gateway.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List

from repro.obs.registry import Registry

__all__ = ["write_chrome_trace", "write_event_array", "read_chrome_trace",
           "prometheus_text", "SNAPSHOT_EVENT"]

#: name of the instant event carrying the final registry snapshot
SNAPSHOT_EVENT = "repro.registry_snapshot"


def _json_line(obj: Dict[str, Any]) -> str:
    # histograms carry inf min/max before the first sample; trace JSON
    # must stay strict-JSON for Perfetto, so map non-finite to null
    def fix(v):
        if isinstance(v, float) and not math.isfinite(v):
            return None
        return v

    return json.dumps(obj, default=fix, allow_nan=False, sort_keys=True)


def _sanitize_tree(obj):
    """Replace non-finite floats with None, recursively (strict JSON)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _sanitize_tree(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize_tree(v) for v in obj]
    return obj


def write_event_array(path: str, events: List[Dict[str, Any]]) -> str:
    """Write trace events as a JSON array, one event per line (the dual
    JSON/JSONL dialect :func:`read_chrome_trace` parses); returns ``path``."""
    with open(path, "w") as f:
        f.write("[\n")
        for i, ev in enumerate(events):
            comma = "," if i + 1 < len(events) else ""
            f.write(_json_line(ev) + comma + "\n")
        f.write("]\n")
    return path


def write_chrome_trace(registry: Registry, path: str, *,
                       process_name: str = "repro") -> str:
    """Dump the registry's trace ring (+ final snapshot) as a
    Perfetto-loadable trace file; returns ``path``."""
    identity = dict(registry.identity)
    if "rank" in identity:
        process_name = f"{process_name} [rank {identity['rank']}]"
    events: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": registry.pid,
         "args": {"name": process_name,
                  **({"identity": identity} if identity else {})}},
    ]
    events.extend(registry.events())
    events.append({
        "name": SNAPSHOT_EVENT, "ph": "i", "s": "p", "pid": registry.pid,
        "tid": registry.tid(), "ts": 0.0,
        "args": {"snapshot": _sanitize_tree(registry.snapshot())}})
    return write_event_array(path, events)


def read_chrome_trace(path: str) -> List[Dict[str, Any]]:
    """Parse a trace written by :func:`write_chrome_trace` (tolerates the
    plain-JSONL and unterminated-array dialects of the format too)."""
    with open(path) as f:
        text = f.read()
    stripped = text.strip()
    if stripped.startswith("["):
        try:
            return json.loads(stripped)
        except json.JSONDecodeError:
            pass  # unterminated array: fall through to per-line parsing
    events = []
    for line in stripped.splitlines():
        line = line.strip().rstrip(",")
        if line in ("", "[", "]"):
            continue
        events.append(json.loads(line))
    return events


def _prom_name(name: str, suffix: str = "") -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name) + suffix


def _prom_escape(v: Any) -> str:
    """Escape a label value per the text-exposition spec: backslash,
    double-quote, and line-feed are the three characters that break the
    ``name{k="v"} value`` line grammar."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _prom_labels(labels: Dict[str, str],
                 extra: Dict[str, str] = None) -> str:
    items = dict(labels)
    items.update(extra or {})
    if not items:
        return ""
    body = ",".join(f'{_prom_name(k)}="{_prom_escape(v)}"'
                    for k, v in sorted(items.items()))
    return "{" + body + "}"


def _prom_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text(registry: Registry) -> str:
    """The registry in Prometheus text exposition format (version 0.0.4)."""
    snap = registry.snapshot()
    lines: List[str] = []
    typed: set = set()

    def header(name: str, kind: str):
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for c in snap["counters"]:
        name = _prom_name(c["name"], "_total")
        header(name, "counter")
        lines.append(f"{name}{_prom_labels(c['labels'])} "
                     f"{_prom_value(c['value'])}")
    for g in snap["gauges"]:
        if isinstance(g["value"], float) and math.isnan(g["value"]):
            continue    # a never-set gauge has no meaningful sample to expose
        name = _prom_name(g["name"])
        header(name, "gauge")
        lines.append(f"{name}{_prom_labels(g['labels'])} "
                     f"{_prom_value(g['value'])}")
    for h in snap["histograms"]:
        name = _prom_name(h["name"])
        header(name, "histogram")
        labels = h["labels"]
        b = h["buckets_per_doubling"]
        cum = h["zero_count"]
        for i_str, n in h["buckets"].items():   # already index-sorted
            cum += n
            le = 2.0 ** ((int(i_str) + 1) / b)
            lines.append(
                f"{name}_bucket{_prom_labels(labels, {'le': repr(le)})} "
                f"{cum}")
        lines.append(f"{name}_bucket{_prom_labels(labels, {'le': '+Inf'})} "
                     f"{h['count']}")
        lines.append(f"{name}_sum{_prom_labels(labels)} "
                     f"{_prom_value(h['sum'])}")
        lines.append(f"{name}_count{_prom_labels(labels)} {h['count']}")
    return "\n".join(lines) + "\n"
