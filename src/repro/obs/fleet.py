"""Fleet-scope telemetry: per-rank identity, rank trace files, merging.

Each rank in a fleet run owns its own :class:`~repro.obs.Registry`,
stamped with its coordinates (:func:`stamp_identity`) and exported to a
per-rank trace file (:func:`write_rank_trace`, one
``rank00000.trace.jsonl`` per process under ``--telemetry-dir``).
:func:`merge_traces` then folds N such files into ONE Perfetto-loadable
timeline:

* every rank becomes its own named track (``pid`` remapped to the rank,
  with ``process_name`` / ``process_sort_index`` metadata so Perfetto
  shows ``rank 0``, ``rank 1``, ... top-to-bottom);
* per-rank monotonic clocks are aligned onto a shared axis using the
  wall-clock ``epoch`` each registry stamps at creation (offset =
  ``(epoch_rank - min_epoch)`` — NTP-grade alignment, which is what a
  straggler investigation needs; sub-ms skew is not promised);
* ``straggler.flagged`` events (recorded by ``StragglerPolicy`` on the
  rank that ran the evaluation) are re-emitted as overlay instants *on
  the flagged rank's own track*, so the slow rank is visually marked;
* the per-rank registry snapshots are merged into one snapshot whose
  instruments carry a ``rank`` label, so ``obs_report`` renders per-rank
  tables from the merged file exactly as it does for a single trace.

Merging is pure host-side JSON shuffling — no jax import, no device
touch; :func:`stamp_process_identity` imports jax lazily only to ask
for ``process_index``.
"""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.export import SNAPSHOT_EVENT, read_chrome_trace, \
    write_chrome_trace, write_event_array
from repro.obs.registry import Registry

__all__ = ["stamp_identity", "stamp_process_identity", "rank_trace_path",
           "write_rank_trace", "discover_rank_traces", "merge_traces",
           "MergeError"]

_RANK_FILE_RE = re.compile(r"rank(\d+)\.trace\.jsonl$")

#: overlay event name drawn on a flagged rank's own track after a merge
STRAGGLER_OVERLAY = "straggler.straggling"


class MergeError(ValueError):
    """A per-rank trace is unusable (unparseable / no embedded snapshot)."""


def stamp_identity(registry: Registry, *, rank: int, **coords) -> Registry:
    """Stamp fleet coordinates onto a registry. ``rank`` is the global
    process index; pod/data mesh coordinates ride along as extra keys."""
    return registry.set_identity(rank=int(rank), **coords)


def stamp_process_identity(registry: Registry, **coords) -> Registry:
    """Stamp this jax process's own coordinates (lazy jax import)."""
    import jax
    return stamp_identity(registry, rank=jax.process_index(),
                          world=jax.process_count(), **coords)


def rank_trace_path(directory: str, rank: int) -> str:
    return os.path.join(directory, f"rank{int(rank):05d}.trace.jsonl")


def write_rank_trace(registry: Registry, directory: str, *,
                     process_name: str = "repro") -> str:
    """Export one rank's trace to its slot under ``directory`` (created
    if needed); the rank comes from the registry's stamped identity."""
    os.makedirs(directory, exist_ok=True)
    rank = int(registry.identity.get("rank", 0))
    return write_chrome_trace(registry, rank_trace_path(directory, rank),
                              process_name=process_name)


def discover_rank_traces(directory: str) -> List[str]:
    paths = sorted(p for p in glob.glob(os.path.join(directory, "*"))
                   if _RANK_FILE_RE.search(p))
    if not paths:
        raise MergeError(f"no rank*.trace.jsonl files under {directory!r}")
    return paths


def _load_rank(path: str, fallback_rank: int) -> Dict[str, Any]:
    try:
        events = read_chrome_trace(path)
    except Exception as e:                      # unparseable / truncated
        raise MergeError(f"cannot parse {path!r}: {e}") from e
    if not events:
        raise MergeError(f"{path!r} is empty")
    snap: Optional[Dict[str, Any]] = None
    for ev in reversed(events):
        if ev.get("name") == SNAPSHOT_EVENT:
            snap = ev.get("args", {}).get("snapshot")
            break
    if snap is None:
        raise MergeError(f"{path!r} has no embedded registry snapshot "
                         f"({SNAPSHOT_EVENT} event)")
    identity = snap.get("identity") or {}
    m = _RANK_FILE_RE.search(path)
    rank = int(identity.get("rank",
                            m.group(1) if m else fallback_rank))
    return {"path": path, "events": events, "snapshot": snap,
            "identity": identity, "rank": rank,
            "epoch": snap.get("epoch")}


def _rank_label(rank: int, identity: Dict[str, Any]) -> str:
    extras = ", ".join(f"{k}={identity[k]}" for k in sorted(identity)
                       if k not in ("rank",))
    return f"rank {rank}" + (f" ({extras})" if extras else "")


def merge_traces(paths: Sequence[str], out_path: str) -> Dict[str, Any]:
    """Merge per-rank trace files into one timeline at ``out_path``;
    returns a summary dict (ranks, event count, overlay count)."""
    ranks = [_load_rank(p, i) for i, p in enumerate(paths)]
    ranks.sort(key=lambda r: r["rank"])
    seen = [r["rank"] for r in ranks]
    if len(set(seen)) != len(seen):
        raise MergeError(f"duplicate ranks across inputs: {seen}")

    epochs = [r["epoch"] for r in ranks if isinstance(r["epoch"], (int, float))]
    epoch0 = min(epochs) if epochs else None

    merged: List[Dict[str, Any]] = []
    flag_events: List[Dict[str, Any]] = []
    for r in ranks:
        rank = r["rank"]
        off_us = ((r["epoch"] - epoch0) * 1e6
                  if epoch0 is not None and
                  isinstance(r["epoch"], (int, float)) else 0.0)
        merged.append({"name": "process_name", "ph": "M", "pid": rank,
                       "args": {"name": _rank_label(rank, r["identity"]),
                                "identity": r["identity"]}})
        merged.append({"name": "process_sort_index", "ph": "M", "pid": rank,
                       "args": {"sort_index": rank}})
        for ev in r["events"]:
            if ev.get("ph") == "M" or ev.get("name") == SNAPSHOT_EVENT:
                continue
            ev = dict(ev)
            ev["pid"] = rank
            ev["ts"] = float(ev.get("ts", 0.0)) + off_us
            merged.append(ev)
            if ev.get("name") == "straggler.flagged":
                flag_events.append(ev)

    # overlay each flag on the flagged rank's own track
    overlays = 0
    valid = set(seen)
    for ev in flag_events:
        flagged = str(ev.get("args", {}).get("ranks", ""))
        for tok in filter(None, (t.strip() for t in flagged.split(","))):
            try:
                fr = int(tok)
            except ValueError:
                continue
            if fr not in valid:
                continue
            merged.append({
                "name": STRAGGLER_OVERLAY, "ph": "i", "s": "p",
                "pid": fr, "tid": 0, "ts": ev["ts"],
                "args": {"flagged_by_rank": ev["pid"],
                         **{k: v for k, v in ev.get("args", {}).items()
                            if k != "ranks"}}})
            overlays += 1

    combined: Dict[str, Any] = {
        "counters": [], "gauges": [], "histograms": [],
        "dropped_events": 0, "epoch": epoch0,
        "identity": {"merged_ranks": seen}}
    for r in ranks:
        snap = r["snapshot"]
        combined["dropped_events"] += int(snap.get("dropped_events", 0))
        for kind in ("counters", "gauges", "histograms"):
            for inst in snap.get(kind, []):
                inst = dict(inst)
                inst["labels"] = {"rank": str(r["rank"]),
                                  **(inst.get("labels") or {})}
                combined[kind].append(inst)
    merged.append({"name": SNAPSHOT_EVENT, "ph": "i", "s": "p",
                   "pid": seen[0], "tid": 0, "ts": 0.0,
                   "args": {"snapshot": combined}})

    write_event_array(out_path, merged)
    return {"out": out_path, "ranks": seen, "events": len(merged),
            "straggler_overlays": overlays}
