"""Zero-sync telemetry: metric registry, spans, Perfetto/Prometheus export.

Quick tour (full model + design rules in ``docs/observability.md``)::

    from repro import obs

    reg = obs.get_registry()              # process-wide default
    reg.counter("server.admitted").inc()
    reg.gauge("server.occupancy").set(0.73)
    with reg.span("server.tick", phase="decode"):
        ...                               # host wall-clock; no device sync
    reg.histogram("server.tick.seconds").percentile(99)

    obs.write_chrome_trace(reg, "run.trace.jsonl")   # load in Perfetto
    print(obs.prometheus_text(reg))                  # /metrics payload

Every runtime component (``SimServer``, ``RolloutEngine``, ``Trainer``)
takes ``registry=``: ``None`` means the process default; ``obs.NULL``
disables its telemetry entirely (no-op instruments — the bit-parity
tests in ``tests/test_obs.py`` drive both paths).
"""
from repro.obs import fleet
from repro.obs.cost import CostAccounted, compiled_cost, record_compiled_cost
from repro.obs.export import (SNAPSHOT_EVENT, prometheus_text,
                              read_chrome_trace, write_chrome_trace)
from repro.obs.flight import FlightRecorder
from repro.obs.registry import (NULL, Counter, Gauge, Histogram, Registry,
                                get_registry, set_registry)

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "NULL",
           "get_registry", "set_registry", "write_chrome_trace",
           "read_chrome_trace", "prometheus_text", "SNAPSHOT_EVENT",
           "CostAccounted", "compiled_cost", "record_compiled_cost",
           "FlightRecorder", "fleet"]
