"""Postmortem flight recorder: one JSON bundle of "what was happening".

When a run dies — Trainer NaN-halt, SIGTERM preemption, or an operator
asking a live :class:`~repro.runtime.sim_server.SimServer` for
``dump_postmortem()`` — the question is always the same: what was the
system doing in the seconds before? The registry already holds the
answer in bounded memory (the trace-event ring + instrument aggregates);
this module packages it, together with component state providers (per-
slot SimServer phase/cursor/scene ids, Trainer loss tail) and the
compiled-cost tables, into a single self-contained JSON bundle that
``python -m repro.launch.obs_report --postmortem`` renders.

Zero-sync contract: a dump reads host-side python state only — the
trace ring, instrument snapshots, and whatever the registered providers
return from their own host bookkeeping. Nothing here blocks on a device
value; a dump is safe from a signal-driven shutdown path. Writes are
atomic (temp file + rename) so a dying process never leaves a torn
bundle behind.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs.export import _sanitize_tree
from repro.obs.registry import Registry, get_registry

__all__ = ["FlightRecorder", "BUNDLE_KIND"]

#: ``kind`` tag identifying a flight-recorder bundle on disk
BUNDLE_KIND = "repro.flight_recorder"

#: default number of most-recent trace events preserved in a bundle
DEFAULT_LAST_K = 2048


class FlightRecorder:
    """Bounded postmortem capture over a registry + state providers.

    ``add_provider(name, fn)`` registers a zero-arg callable returning
    JSON-able host state (components register themselves: SimServer its
    per-slot table, Trainer its step/NaN/loss tail). ``dump(reason=...)``
    snapshots everything into one bundle file. A provider that raises is
    recorded as an error entry instead of killing the dump — a postmortem
    path must never add its own crash.
    """

    def __init__(self, registry: Optional[Registry] = None, *,
                 out_path: Optional[str] = None,
                 last_k: int = DEFAULT_LAST_K):
        self.obs = registry if registry is not None else get_registry()
        self.out_path = out_path
        self.last_k = int(last_k)
        self._providers: Dict[str, Callable[[], Any]] = {}

    def add_provider(self, name: str, fn: Callable[[], Any]
                     ) -> "FlightRecorder":
        self._providers[name] = fn
        return self

    def bundle(self, reason: str = "manual", **context) -> Dict[str, Any]:
        """Assemble the postmortem bundle (pure host state, no I/O)."""
        events: List[Dict[str, Any]] = self.obs.events()
        state: Dict[str, Any] = {}
        for name, fn in self._providers.items():
            try:
                state[name] = fn()
            except Exception as e:      # noqa: BLE001 — never crash a dump
                state[name] = {"error": f"{type(e).__name__}: {e}"}
        return _sanitize_tree({
            "kind": BUNDLE_KIND,
            "version": 1,
            "reason": reason,
            "wall_time_unix": time.time(),
            "identity": dict(self.obs.identity),
            "context": context,
            "state": state,
            "snapshot": self.obs.snapshot(),
            "trace_events_total": len(events) + self.obs.dropped_events,
            "events": events[-self.last_k:],
        })

    def dump(self, reason: str = "manual", path: Optional[str] = None,
             **context) -> str:
        """Write the bundle as JSON (atomically); returns the path."""
        path = path or self.out_path
        if path is None:
            raise ValueError("FlightRecorder.dump needs a path (constructor "
                             "out_path= or dump(path=...))")
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        b = self.bundle(reason, **context)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(b, f, indent=1, allow_nan=False)
            f.write("\n")
        os.replace(tmp, path)
        return path
