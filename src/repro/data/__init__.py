"""Data substrate: synthetic generators + sharded, checkpointable pipeline."""
from repro.data import pipeline, scenarios, synthetic_lm
from repro.data.pipeline import ShardedIterator

__all__ = ["pipeline", "scenarios", "synthetic_lm", "ShardedIterator"]
