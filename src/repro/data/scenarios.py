"""Synthetic driving-scenario generator for the agent-simulation task.

The paper trains on a 33M-scenario private dataset; we substitute a
procedural generator with the same interface statistics: lane polylines
(map tokens with SE(2) poses), agents spawned on lanes that follow them
with kinematic-unicycle dynamics + noise, and ground-truth next-action
labels on a discrete (acceleration x yaw-rate) grid.

Everything is numpy (host-side data pipeline); scenes are generated
deterministically from (seed, index) so the pipeline is checkpointable by
cursor alone and shards trivially across data-loader hosts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

DT = 0.5          # seconds per simulation step
MAX_SPEED = 25.0  # m/s clamp in the unicycle integrator
# NOTE: repro.runtime.rollout.step_kinematics is the jnp mirror of
# step_kinematics below (the engine needs it jit-able on device); both
# must integrate identically — tests/test_decode.py pins the parity.


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    num_map: int = 32             # lane-segment tokens per scene
    num_agents: int = 8
    num_steps: int = 16           # history+future steps tokenized
    accel_bins: int = 7           # action grid
    yaw_bins: int = 9
    max_accel: float = 3.0        # m/s^2
    max_yaw_rate: float = 0.5     # rad/s
    map_radius: float = 60.0
    agent_feat_dim: int = 8
    map_feat_dim: int = 8

    @property
    def num_actions(self) -> int:
        return self.accel_bins * self.yaw_bins

    def accel_values(self):
        return np.linspace(-self.max_accel, self.max_accel, self.accel_bins)

    def yaw_values(self):
        return np.linspace(-self.max_yaw_rate, self.max_yaw_rate,
                           self.yaw_bins)


def encode_action(cfg: ScenarioConfig, accel, yaw_rate):
    """Nearest grid cell -> action id."""
    ai = np.argmin(np.abs(cfg.accel_values()[None, :]
                          - np.asarray(accel)[..., None]), axis=-1)
    yi = np.argmin(np.abs(cfg.yaw_values()[None, :]
                          - np.asarray(yaw_rate)[..., None]), axis=-1)
    return ai * cfg.yaw_bins + yi


def decode_action(cfg: ScenarioConfig, action_id):
    ai, yi = np.divmod(np.asarray(action_id), cfg.yaw_bins)
    return cfg.accel_values()[ai], cfg.yaw_values()[yi]


def step_kinematics(pose, speed, accel, yaw_rate, dt: float = DT):
    """Unicycle integration; pose (..., 3), returns (new_pose, new_speed)."""
    speed_new = np.clip(speed + accel * dt, 0.0, MAX_SPEED)
    theta_new = pose[..., 2] + yaw_rate * dt
    mid_speed = 0.5 * (speed + speed_new)
    x = pose[..., 0] + mid_speed * np.cos(theta_new) * dt
    y = pose[..., 1] + mid_speed * np.sin(theta_new) * dt
    return np.stack([x, y, theta_new], axis=-1), speed_new


def _make_lanes(rng, cfg: ScenarioConfig):
    """A few arcs/straights through the scene; returns per-segment pose+feat."""
    poses = np.zeros((cfg.num_map, 3), np.float32)
    feats = np.zeros((cfg.num_map, cfg.map_feat_dim), np.float32)
    n_lanes = rng.integers(2, 5)
    seg_per_lane = cfg.num_map // n_lanes
    idx = 0
    lanes = []
    for li in range(n_lanes):
        start = rng.uniform(-cfg.map_radius * 0.5, cfg.map_radius * 0.5, 2)
        heading = rng.uniform(-np.pi, np.pi)
        curvature = rng.uniform(-0.02, 0.02)
        seg_len = rng.uniform(5.0, 10.0)
        pts = []
        x, y, th = start[0], start[1], heading
        for si in range(seg_per_lane):
            if idx >= cfg.num_map:
                break
            poses[idx] = (x, y, th)
            feats[idx, 0] = seg_len / 10.0
            feats[idx, 1] = curvature * 50.0
            feats[idx, 2] = 1.0  # type: lane
            feats[idx, 3] = li / n_lanes
            pts.append((x, y, th, seg_len))
            x += seg_len * np.cos(th)
            y += seg_len * np.sin(th)
            th += curvature * seg_len
            idx += 1
        lanes.append(pts)
    return poses, feats, lanes


def generate_scene(seed: int, index: int, cfg: ScenarioConfig
                   ) -> Dict[str, np.ndarray]:
    """One scene: map tokens, agent rollouts, and next-action labels.

    Returns arrays shaped for ``AgentSimModel``:
      map_feats (M, Fm), map_pose (M, 3), map_valid (M,)
      agent_feats (T, A, Fa), agent_pose (T, A, 3), agent_valid (T, A)
      actions (T, A) int32   — action taken between t and t+1
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, index]))
    map_pose, map_feats, lanes = _make_lanes(rng, cfg)

    a, t = cfg.num_agents, cfg.num_steps
    pose = np.zeros((a, 3), np.float32)
    speed = rng.uniform(0.0, 12.0, a).astype(np.float32)
    behavior = rng.integers(0, 3, a)  # 0 stationary-ish, 1 straight, 2 turny
    for ai in range(a):
        lane = lanes[rng.integers(0, len(lanes))]
        seg = lane[rng.integers(0, len(lane))]
        pose[ai] = (seg[0] + rng.normal(0, 1.0), seg[1] + rng.normal(0, 1.0),
                    seg[2] + rng.normal(0, 0.1))
        if behavior[ai] == 0:
            speed[ai] = rng.uniform(0, 0.5)

    agent_pose = np.zeros((t, a, 3), np.float32)
    agent_feats = np.zeros((t, a, cfg.agent_feat_dim), np.float32)
    actions = np.zeros((t, a), np.int64)
    cur_pose, cur_speed = pose, speed
    for ti in range(t):
        agent_pose[ti] = cur_pose
        agent_feats[ti, :, 0] = cur_speed / 10.0
        agent_feats[ti, :, 1] = (behavior == 1)
        agent_feats[ti, :, 2] = (behavior == 2)
        agent_feats[ti, :, 3] = 1.0
        # policy: noisy accel; turny agents sweep yaw rate sinusoidally
        accel = np.where(behavior == 0,
                         -cur_speed / DT * 0.5,
                         rng.normal(0.3, 0.8, a))
        yaw = np.where(behavior == 2,
                       cfg.max_yaw_rate * 0.7
                       * np.sin(0.4 * ti + np.arange(a)),
                       rng.normal(0, 0.03, a))
        accel = np.clip(accel, -cfg.max_accel, cfg.max_accel)
        yaw = np.clip(yaw, -cfg.max_yaw_rate, cfg.max_yaw_rate)
        act_id = encode_action(cfg, accel, yaw)
        actions[ti] = act_id
        # integrate with the *quantized* action so labels are exact
        qa, qy = decode_action(cfg, act_id)
        cur_pose, cur_speed = step_kinematics(cur_pose, cur_speed, qa, qy)

    return {
        "map_feats": map_feats,
        "map_pose": map_pose,
        "map_valid": np.ones(cfg.num_map, bool),
        "agent_feats": agent_feats,
        "agent_pose": agent_pose,
        "agent_valid": np.ones((t, a), bool),
        "actions": actions.astype(np.int32),
        "behavior": behavior.astype(np.int32),
    }


def generate_batch(seed: int, start_index: int, batch_size: int,
                   cfg: ScenarioConfig) -> Dict[str, np.ndarray]:
    scenes = [generate_scene(seed, start_index + i, cfg)
              for i in range(batch_size)]
    return {k: np.stack([s[k] for s in scenes]) for k in scenes[0]}


def rollout_metrics(cfg: ScenarioConfig, gt_pose, sampled_poses, behavior):
    """minADE over samples, split by ground-truth behavior category.

    gt_pose (T, A, 3); sampled_poses (K, T, A, 3); behavior (A,).
    Returns dict of minADE per category (paper Table I columns).
    """
    d = np.linalg.norm(sampled_poses[..., :2] - gt_pose[None, ..., :2],
                       axis=-1)                     # (K, T, A)
    ade = d.mean(axis=1)                            # (K, A)
    min_ade = ade.min(axis=0)                       # (A,)
    out = {}
    for name, b in (("stationary", 0), ("straight", 1), ("turning", 2)):
        sel = behavior == b
        out[name] = float(min_ade[sel].mean()) if sel.any() else float("nan")
    return out
