"""Back-compat shim over the scenario subsystem (``repro.scenarios``).

The synthetic driving-scenario generator that used to live here is now
the ``freeform`` family of ``repro.scenarios.families`` — one of several
procedural families on the lane-graph world model. This module keeps the
historical surface (``ScenarioConfig``, ``generate_scene``,
``generate_batch``, the action codec, ``step_kinematics``,
``rollout_metrics``) so the data pipeline, benchmarks, and tests keep
working unchanged; ``generate_scene`` returns bit-identical arrays to
every pre-refactor release (the freeform family preserves its original
RNG stream).

New code should import from ``repro.scenarios`` directly.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.kinematics import DT, MAX_SPEED
from repro.core.kinematics import step_kinematics as _step_kinematics
from repro.scenarios.core import (ScenarioConfig, decode_action,
                                  encode_action, rollout_metrics)
from repro.scenarios.families import freeform as _freeform

__all__ = ["DT", "MAX_SPEED", "ScenarioConfig", "encode_action",
           "decode_action", "step_kinematics", "generate_scene",
           "generate_batch", "rollout_metrics"]


def step_kinematics(pose, speed, accel, yaw_rate, dt: float = DT):
    """Unicycle integration; pose (..., 3), returns (new_pose, new_speed).

    Host-side numpy entry point of the shared integrator in
    ``repro.core.kinematics`` (the rollout engine jits the same function
    on jax arrays — one implementation, no twins to keep in sync)."""
    return _step_kinematics(pose, speed, accel, yaw_rate, dt, xp=np)


def generate_scene(seed: int, index: int, cfg: ScenarioConfig
                   ) -> Dict[str, np.ndarray]:
    """One free-form scene: map tokens, agent rollouts, next-action labels.

    Returns arrays shaped for ``AgentSimModel`` (see
    ``repro.scenarios.core.Scene``); identical to the historical output
    plus an ``agent_type`` vector (all vehicles)."""
    tensors, _ = _freeform.generate_tensors(seed, index, cfg)
    return tensors


def generate_batch(seed: int, start_index: int, batch_size: int,
                   cfg: ScenarioConfig) -> Dict[str, np.ndarray]:
    scenes = [generate_scene(seed, start_index + i, cfg)
              for i in range(batch_size)]
    return {k: np.stack([s[k] for s in scenes]) for k in scenes[0]}
