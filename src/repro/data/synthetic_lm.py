"""Synthetic LM token streams (Zipfian n-gram process).

Deterministic per (seed, index): the pipeline's only checkpoint state is its
cursor. The generator has genuine next-token structure (a latent bigram
table) so tiny-model training loss visibly decreases — useful for e2e
trainer tests and example drivers without shipping a corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 256
    seq_len: int = 128
    zipf_a: float = 1.2
    bigram_strength: float = 0.7


def _bigram_table(seed: int, cfg: LMDataConfig) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB16]))
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.vocab_size,)).astype(np.int64)


def generate_sequence(seed: int, index: int, cfg: LMDataConfig) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, index]))
    table = _bigram_table(seed, cfg)
    ranks = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1)
    base = np.minimum(ranks - 1, cfg.vocab_size - 1).astype(np.int64)
    seq = np.empty(cfg.seq_len + 1, np.int64)
    seq[0] = base[0]
    follow = rng.random(cfg.seq_len) < cfg.bigram_strength
    for i in range(1, cfg.seq_len + 1):
        seq[i] = table[seq[i - 1]] if follow[i - 1] else base[i]
    return seq


def generate_batch(seed: int, start_index: int, batch_size: int,
                   cfg: LMDataConfig) -> Dict[str, np.ndarray]:
    seqs = np.stack([generate_sequence(seed, start_index + i, cfg)
                     for i in range(batch_size)])
    return {"tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32)}
