"""Host data pipeline: sharded, prefetched, checkpointable iterators.

Design for 1000+ node clusters:
  * each data-loader host owns a disjoint slice of the index space
    (``index = cursor * world + host_rank``) — no coordination needed;
  * the ONLY pipeline state is the integer cursor, so checkpoint/restore
    and elastic re-sharding (changing ``world``) are trivial and exact;
  * a background thread keeps a small prefetch queue ahead of the step loop
    so host-side generation overlaps device compute.
"""
from __future__ import annotations

import logging
import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional



class ShardedIterator:
    """Deterministic, restartable batch iterator.

    ``make_batch(seed, start_index, batch_size) -> dict of np arrays`` must
    be a pure function (our synthetic generators are; a real corpus reader
    keyed by record index satisfies the same contract).
    """

    def __init__(self, make_batch: Callable[[int, int, int], Dict[str, Any]],
                 batch_size: int, seed: int = 0,
                 host_rank: int = 0, world: int = 1,
                 prefetch: int = 2):
        self.make_batch = make_batch
        self.batch_size = batch_size
        self.seed = seed
        self.host_rank = host_rank
        self.world = world
        self.cursor = 0
        self._prefetch = prefetch
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        # batch_size / world are recorded for observability: restoring under
        # a different world is SUPPORTED (elastic re-sharding — the cursor
        # semantics stay exact), but it changes which records each host sees,
        # so a mismatch is worth a log line rather than silence.
        return {"cursor": self.cursor, "seed": self.seed,
                "batch_size": self.batch_size, "world": self.world}

    def load_state_dict(self, state: Dict[str, int]):
        self._drain()
        for key in ("batch_size", "world"):
            if key in state and int(state[key]) != getattr(self, key):
                logging.getLogger("repro.data").warning(
                    "ShardedIterator restored with %s=%d (checkpoint had "
                    "%d); cursor semantics stay exact but the record->host "
                    "assignment changes", key, getattr(self, key),
                    int(state[key]))
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])

    # -- iteration -----------------------------------------------------------
    def _index_for(self, cursor: int) -> int:
        return (cursor * self.world + self.host_rank) * self.batch_size

    def _produce(self, cursor: int):
        return self.make_batch(self.seed, self._index_for(cursor),
                               self.batch_size)

    def _worker(self):
        cursor = self.cursor
        while not self._stop.is_set():
            batch = self._produce(cursor)
            while not self._stop.is_set():
                try:
                    self._queue.put((cursor, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            cursor += 1

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._queue = queue.Queue(maxsize=self._prefetch)
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _drain(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._queue.get_nowait()
            except (queue.Empty, AttributeError):
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    def __next__(self) -> Dict[str, Any]:
        self._ensure_thread()
        cursor, batch = self._queue.get()
        # the queue is strictly ordered, so cursor tracks consumption exactly
        self.cursor = cursor + 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def close(self):
        self._drain()
