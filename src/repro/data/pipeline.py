"""Host data pipeline: sharded, prefetched, checkpointable iterators.

Design for 1000+ node clusters:
  * each data-loader host owns a disjoint slice of the index space
    (``index = cursor * world + host_rank``) — no coordination needed;
  * the ONLY pipeline state is the integer cursor, so checkpoint/restore
    and elastic re-sharding (changing ``world``) are trivial and exact;
  * a background thread keeps a small prefetch queue ahead of the step loop
    so host-side generation overlaps device compute;
  * worker failures PROPAGATE: a ``make_batch`` that raises is retried a
    bounded number of times inside the worker (transient blips — a flaky
    filesystem, a remote reader hiccup), and if it still fails the error
    travels through the queue and ``__next__`` raises
    :class:`DataWorkerError`. The consumer never hangs on a dead worker,
    and a deterministic ``make_batch`` bug can never become a silent
    respawn-forever loop (the drill: ``repro.chaos.flaky_make_batch``).
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple


class DataWorkerError(RuntimeError):
    """The prefetch worker's ``make_batch`` failed (after its bounded
    retries) or the worker died without delivering; raised on the
    consumer thread by ``__next__``. The cursor is NOT advanced past the
    failed batch — a retry after fixing the cause resumes exactly
    there."""


class ShardedIterator:
    """Deterministic, restartable batch iterator.

    ``make_batch(seed, start_index, batch_size) -> dict of np arrays`` must
    be a pure function (our synthetic generators are; a real corpus reader
    keyed by record index satisfies the same contract).

    ``worker_retries``: extra in-worker attempts after a ``make_batch``
    failure, with ``retry_backoff * 2**i`` seconds between attempts,
    before the error is delivered to the consumer.
    """

    def __init__(self, make_batch: Callable[[int, int, int], Dict[str, Any]],
                 batch_size: int, seed: int = 0,
                 host_rank: int = 0, world: int = 1,
                 prefetch: int = 2, worker_retries: int = 2,
                 retry_backoff: float = 0.05):
        self.make_batch = make_batch
        self.batch_size = batch_size
        self.seed = seed
        self.host_rank = host_rank
        self.world = world
        self.cursor = 0
        self.worker_retries = int(worker_retries)
        self.retry_backoff = float(retry_backoff)
        self._prefetch = prefetch
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        # batch_size / world are recorded for observability: restoring under
        # a different world is SUPPORTED (elastic re-sharding — the cursor
        # semantics stay exact), but it changes which records each host sees,
        # so a mismatch is worth a log line rather than silence.
        return {"cursor": self.cursor, "seed": self.seed,
                "batch_size": self.batch_size, "world": self.world}

    def load_state_dict(self, state: Dict[str, int]):
        self._drain()
        for key in ("batch_size", "world"):
            if key in state and int(state[key]) != getattr(self, key):
                logging.getLogger("repro.data").warning(
                    "ShardedIterator restored with %s=%d (checkpoint had "
                    "%d); cursor semantics stay exact but the record->host "
                    "assignment changes", key, getattr(self, key),
                    int(state[key]))
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])

    # -- iteration -----------------------------------------------------------
    def _index_for(self, cursor: int) -> int:
        return (cursor * self.world + self.host_rank) * self.batch_size

    def _produce(self, cursor: int):
        return self.make_batch(self.seed, self._index_for(cursor),
                               self.batch_size)

    def _produce_with_retries(self, cursor: int):
        for attempt in range(self.worker_retries + 1):
            try:
                return self._produce(cursor)
            except Exception:
                if attempt >= self.worker_retries or self._stop.is_set():
                    raise
                logging.getLogger("repro.data").warning(
                    "make_batch failed at cursor %d (attempt %d/%d); "
                    "retrying", cursor, attempt + 1, self.worker_retries + 1,
                    exc_info=True)
                time.sleep(self.retry_backoff * (2 ** attempt))

    def _put(self, item: Tuple[int, Any, bool]) -> bool:
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        cursor = self.cursor
        while not self._stop.is_set():
            try:
                batch = self._produce_with_retries(cursor)
            except Exception as e:      # noqa: BLE001 — delivered, not lost
                # deliver the failure and EXIT: the old behavior (die
                # silently, get respawned by _ensure_thread from the
                # stale self.cursor) turned any deterministic
                # make_batch bug into an invisible infinite respawn loop
                self._put((cursor, e, True))
                return
            if not self._put((cursor, batch, False)):
                return
            cursor += 1

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            # a dead worker always leaves its parting error on the queue
            # (consumed by __next__ below); respawns only happen after
            # that error has been raised, from the un-advanced cursor
            if self._thread is not None and self._queue is not None \
                    and not self._queue.empty():
                return
            self._stop.clear()
            self._queue = queue.Queue(maxsize=self._prefetch)
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _drain(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._queue.get_nowait()
            except (queue.Empty, AttributeError):
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    def __next__(self) -> Dict[str, Any]:
        self._ensure_thread()
        while True:
            try:
                cursor, payload, is_err = self._queue.get(timeout=1.0)
                break
            except queue.Empty:
                if self._thread is None or not self._thread.is_alive():
                    # worker died; one last non-blocking look in case it
                    # delivered between our timeout and the liveness check
                    try:
                        cursor, payload, is_err = self._queue.get_nowait()
                        break
                    except queue.Empty:
                        # died without delivering (interpreter teardown,
                        # thread killed): fail loudly, never hang
                        self._thread = None
                        raise DataWorkerError(
                            f"data worker died without delivering a batch "
                            f"(cursor {self.cursor})") from None
        if is_err:
            self._drain()
            raise DataWorkerError(
                f"make_batch failed at cursor {cursor} (start index "
                f"{self._index_for(cursor)}) after "
                f"{self.worker_retries + 1} attempts: {payload}") \
                from payload
        # the queue is strictly ordered, so cursor tracks consumption exactly
        self.cursor = cursor + 1
        return payload

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def close(self):
        self._drain()
